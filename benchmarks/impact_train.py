"""Online in-memory training benchmark: the PR-10 acceptance artifact.

One deployed system takes live-traffic serving sweeps interleaved with
``OnlineTrainer`` update sweeps, all through the same compiled-session
runtime.  Four gated sections land in ``BENCH_train.json``
(``check_perf.py --train`` enforces them):

* **parity** — the Pallas ``ta_feedback`` kernel and the einsum oracle
  must walk bit-identical TA/weight trajectories (all stochastic
  feedback draws are precomputed operands, so EXACT equality, not a
  tolerance).
* **online** — held-out accuracy on the synthetic glyph problem must
  improve over the pre-deployment accuracy and clear the stored floor
  after N update sweeps (ideal devices, so the figure is deterministic).
* **write_meter / read_billing** — the f64 sum of per-update write
  bills must equal the running write meter and the aggregated report
  lane at 1e-9, and per-request read bills must keep reconciling with
  the batch meter at 1e-9 while updates mutate the fabric under the
  serving executable.
* **serving_only** — pure inference reports bill exactly 0.0 J of
  write energy.

``--quick`` shrinks the update count for the CI perf-smoke job (the
accuracy floor is stored per scale).  A Chrome trace of the interleaved
run (serve spans + train_update spans) lands next to the JSON.

CSV rows:  impact_train/update_b<B>, us_per_update, updates_per_s
           impact_train/serve_b<B>, us_per_sweep, samples_per_s
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ARTIFACTS, emit

from repro.core import CoTMConfig
from repro.core.train import train_step_batch
from repro.data.synthetic import prototype
from repro.impact import IMPACTConfig, RuntimeSpec, build_system
from repro.serve.impact_engine import aggregate_reports
from repro.serve.tracing import Tracer
from repro.train import OnlineTrainer

BATCH = 64


def _problem(seed=3):
    cfg = CoTMConfig(n_literals=64, n_clauses=40, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(640, n_classes=4, n_features=32, flip=0.05, seed=seed)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    return cfg, (lits[:512], labels[:512]), (lits[512:], labels[512:])


def _deploy(cfg, tr_l, tr_y, *, backend, seed=0):
    """One digital pre-train epoch (a half-trained deployment), then
    encode into an ideal-device system (deterministic accuracy; the
    trainer itself owns the write-path noise model)."""
    params = cfg.init(jax.random.key(seed))
    key = jax.random.key(seed + 1)
    for b in range(0, 512, BATCH):
        key, k = jax.random.split(key)
        params = train_step_batch(params, tr_l[b:b + BATCH],
                                  tr_y[b:b + BATCH], k, cfg)
    system = build_system(params, cfg, jax.random.key(seed + 2),
                          IMPACTConfig(variability=False, finetune=False))
    session = system.compile(RuntimeSpec(backend=backend, interpret=True))
    return params, system, session


def parity_sweep(cfg, tr_l, tr_y, n_steps=3):
    """Oracle-vs-kernel TA-state parity: two trainers differing only in
    backend, same keys, must agree EXACTLY after every update."""
    states = {}
    for backend in ("xla", "pallas"):
        params, _, session = _deploy(cfg, tr_l, tr_y, backend=backend)
        trainer = OnlineTrainer(session, params, cfg,
                                key=jax.random.key(11), variability=True)
        for step in range(n_steps):
            trainer.update(tr_l[step * BATCH:(step + 1) * BATCH],
                           tr_y[step * BATCH:(step + 1) * BATCH],
                           key=jax.random.key(100 + step))
        states[backend] = trainer
    a, b = states["xla"], states["pallas"]
    exact = bool(
        np.array_equal(np.asarray(a.params.ta_state),
                       np.asarray(b.params.ta_state))
        and np.array_equal(np.asarray(a.params.weights),
                           np.asarray(b.params.weights))
        and a.write_energy_j == b.write_energy_j)
    return {"exact": exact, "n_steps": n_steps,
            "write_energy_j": a.write_energy_j}


def interleaved_run(cfg, splits, *, epochs, trace_dir):
    (tr_l, tr_y), (ho_l, ho_y) = splits
    params, system, session = _deploy(cfg, tr_l, tr_y, backend="pallas")
    trace = Tracer()
    trainer = OnlineTrainer(session, params, cfg, key=jax.random.key(7),
                            variability=False, trace=trace)
    acc_before = trainer.evaluate(ho_l, ho_y)
    session.warm(BATCH, "infer_step")

    serve_us, update_us, max_read_rel_err = [], [], 0.0
    serving_write_j = None
    for epoch in range(epochs):
        for b in range(0, 512, BATCH):
            lo = tr_l[b:b + BATCH]
            t0 = time.perf_counter()
            ts0 = trace.clock()
            res = session.infer_step(np.asarray(lo, np.int8),
                                     np.ones((BATCH,), bool))
            jax.block_until_ready(res.predictions)
            trace.span("serve_sweep", ts0, trace.clock())
            serve_us.append((time.perf_counter() - t0) * 1e6)

            e_cl = np.asarray(res.e_clause_lanes, np.float64)
            e_cs = np.asarray(res.e_class_lanes, np.float64)
            rep = system.step_report(e_cl, e_cs, BATCH)
            lane_sum = e_cl.sum() + e_cs.sum()
            if lane_sum > 0.0:
                max_read_rel_err = max(
                    max_read_rel_err,
                    abs(rep.read_energy_j - lane_sum) / lane_sum)
            serving_write_j = rep.write_energy_j

            t0 = time.perf_counter()
            trainer.update(lo, tr_y[b:b + BATCH])
            update_us.append((time.perf_counter() - t0) * 1e6)

    acc_after = trainer.evaluate(ho_l, ho_y)
    per_update_sum = sum(r["write_energy_j"] for r in trainer.records)
    agg = aggregate_reports(trainer.reports)
    meter = trainer.write_energy_j
    trace.write(trace_dir / "impact_train.trace.json")

    emit(f"impact_train/update_b{BATCH}", float(np.mean(update_us)),
         f"{1e6 / np.mean(update_us):.1f}")
    emit(f"impact_train/serve_b{BATCH}", float(np.mean(serve_us)),
         f"{BATCH * 1e6 / np.mean(serve_us):.1f}")
    return {
        "online": {
            "acc_before": acc_before, "acc_after": acc_after,
            "n_updates": len(trainer.records),
            "write_energy_j": meter,
            "prog_pulses": sum(r["prog_pulses"] for r in trainer.records),
            "erase_pulses": sum(r["erase_pulses"] for r in trainer.records),
            "n_unconverged": sum(r["n_unconverged"]
                                 for r in trainer.records),
            "us_per_update": float(np.mean(update_us)),
        },
        "write_meter": {
            "per_update_sum_j": per_update_sum,
            "running_meter_j": meter,
            "aggregate_j": agg.write_energy_j,
            "rel_err": (abs(per_update_sum - meter) / meter
                        if meter > 0.0 else 0.0),
        },
        "read_billing": {"max_rel_err": max_read_rel_err},
        "serving_only": {"write_energy_j": serving_write_j},
    }


def main(quick: bool = False, json_dir=None):
    json_dir = pathlib.Path(json_dir) if json_dir else ARTIFACTS
    json_dir.mkdir(parents=True, exist_ok=True)
    cfg, train, holdout = _problem()
    epochs = 2 if quick else 6
    bench = {"quick": quick, "batch": BATCH, "epochs": epochs,
             # Deterministic (ideal devices, fixed keys): quick clears
             # ~0.75 after 16 updates, full ~0.85 after 48 — floors sit
             # well below so a legitimate refactor has headroom while a
             # broken feedback path (which collapses to ~0.3) still trips.
             "acc_floor": 0.55 if quick else 0.65}
    bench["parity"] = parity_sweep(cfg, *train)
    bench.update(interleaved_run(cfg, (train, holdout), epochs=epochs,
                                 trace_dir=json_dir))
    with open(json_dir / "BENCH_train.json", "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    import warnings

    from repro.impact import SpecDeprecationWarning

    warnings.simplefilter("error", SpecDeprecationWarning)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI perf-smoke scale: fewer update epochs")
    ap.add_argument("--json-dir", default=None,
                    help="where BENCH_train.json lands (default: artifacts/)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick, json_dir=args.json_dir)
