"""IMPACT serving throughput: einsum-vs-Pallas analog inference sweep.

Measures ``IMPACTSystem.predict`` samples/s at the paper's MNIST dims
(K=1568, n=500, m=10) across batch sizes, for both ``impl="xla"`` (the
einsum oracle) and ``impl="pallas"`` (the fused crossbar kernel —
interpret mode on CPU, so CPU numbers gauge correctness plumbing and
XLA-vs-kernel dispatch overhead rather than TPU speed), plus the batched
``IMPACTEngine`` front end to expose queueing + padding overhead.

CSV rows:  impact_throughput/<impl>_b<B>, us_per_batch, samples_per_s
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

from repro.core import CoTMConfig
from repro.impact import IMPACTConfig, build_system
from repro.serve import IMPACTEngine

BATCH_SIZES = (32, 128, 512)
REPEATS = 3


def _random_cotm(key, K=1568, n=500, m=10, n_states=128, density=0.05):
    """Random (untrained) CoTM at paper dims — throughput does not depend
    on training quality, and this keeps the benchmark CPU-budget friendly."""
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    k1, k2 = jax.random.split(key)
    ta = jnp.where(jax.random.bernoulli(k1, density, (K, n)),
                   n_states + 1, n_states).astype(jnp.int32)
    w = jax.random.randint(k2, (m, n), -40, 40).astype(jnp.int32)
    params = cfg.init(key)
    params = type(params)(ta_state=ta, weights=w)
    return cfg, params


def _time_predict(system, lits, impl: str) -> float:
    preds = system.predict(lits, impl=impl)          # compile + warm cache
    jax.block_until_ready(preds)
    t0 = time.time()
    for _ in range(REPEATS):
        jax.block_until_ready(system.predict(lits, impl=impl))
    return (time.time() - t0) / REPEATS


def main() -> None:
    key = jax.random.key(0)
    cfg, params = _random_cotm(key)
    # Ideal devices: benchmark the inference path, not encode stochasticity.
    system = build_system(params, cfg, jax.random.key(1),
                          IMPACTConfig(variability=False, finetune=False))

    rng = np.random.default_rng(0)
    for B in BATCH_SIZES:
        lits = jnp.asarray(rng.random((B, cfg.n_literals)) < 0.5)
        for impl in ("xla", "pallas"):
            dt = _time_predict(system, lits, impl)
            emit(f"impact_throughput/{impl}_b{B}", dt * 1e6,
                 f"{B / dt:.1f}")

    # Batched front end: request burst through queue + bucket padding.
    B = max(BATCH_SIZES)
    lits = np.asarray(rng.random((B, cfg.n_literals)) < 0.5)
    eng = IMPACTEngine(system, impl="xla", max_batch=128,
                       meter_energy=False)
    eng.warmup()
    t0 = time.time()
    _, stats = eng.run(lits)
    dt = time.time() - t0
    emit("impact_throughput/engine_xla_burst", dt * 1e6 / stats["batches"],
         f"{B / dt:.1f}")


if __name__ == "__main__":
    main()
