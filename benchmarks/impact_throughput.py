"""IMPACT serving throughput: einsum-vs-Pallas sweep + mixed-traffic serve.

All measurements run through the compiled-session runtime: each
configuration is a frozen ``RuntimeSpec`` resolved once by
``IMPACTSystem.compile`` into an ``InferenceSession`` of AOT executables,
so the timed loops never pay (or hide) jit-cache lookups or retraces.

Four measurements:

1. **Throughput sweep** — ``session.predict`` samples/s at the paper's
   MNIST dims (K=1568, n=500, m=10) across batch sizes, for both
   ``backend="xla"`` (the einsum oracle) and ``backend="pallas"`` (the
   fused crossbar kernel — interpret mode on CPU, so CPU numbers gauge
   correctness plumbing and dispatch overhead rather than TPU speed),
   plus the batched ``IMPACTEngine`` front end to expose queueing +
   padding overhead.  Written to ``BENCH_throughput.json`` with
   machine-portable normalized ratios (each key / its backend family's
   reference at the smallest batch) that CI gates against a committed
   baseline.

2. **Poisson mixed-traffic serve** — the same seeded arrival trace is
   replayed through the continuous-batching scheduler and the legacy
   flush-to-completion scheduler; per-request p50/p95/p99 tail latency and
   throughput of both land in ``BENCH_serve.json``.  This is the PR-2
   acceptance artifact: continuous must show lower p95 at equal offered
   load.

3. **Metered sweep** — prices the in-kernel energy meter: the SAME
   ``infer_step`` sweep through three sessions (``metering="off"`` — the
   unmetered fused kernel, ``"fused"`` — meters accumulated inside the
   fused kernel, ``"staged"`` — the per-shard oracle the fused meters
   are pinned against), with argmax + per-lane-joule parity between the
   two metered modes asserted and recorded.  Lands under the
   ``"metered"`` key of ``BENCH_throughput.json``; ``check_perf.py``
   requires the section, its parity flag, and a sane fused-metered /
   unmetered ratio.

4. **Compressed sweep** — the bit-packed datapath: ``predict`` through
   the ``pallas-packed`` backend (``packing="2bit"`` — 2-bit ternary
   clause codes, four cells per byte, dequantized inside the fused
   kernel) vs the int8-literal/f32-operand fused kernel, with argmax
   parity against the einsum oracle asserted.  The per-batch
   ``cost_analysis`` record carries both XLA ``bytes_accessed`` and the
   exact operand footprint (``session.input_bytes``); ``check_perf.py``
   gates both ratios at >= 4x.  A clause-pruning record
   (``train.compression.prune_clauses`` on a calibration batch) lands
   alongside with the re-anchored energy-per-effective-clause figure.
   Lands under the ``"compressed"`` key of ``BENCH_throughput.json``.

5. **Sharded sweep** (multi-device hosts only) — the same predict path
   from a (data, model=2) mesh via a ``RuntimeSpec`` topology on an
   R=2/S=2 split grid vs the identical split grid on one device, with
   argmax parity asserted; lands under the ``"sharded"`` key of
   ``BENCH_throughput.json`` and is exercised by the CI multi-device leg
   under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--quick`` shrinks the sweep (B<=32) for the CI perf-smoke job.

CSV rows:  impact_throughput/<impl>_b<B>, us_per_batch, samples_per_s
           impact_metered/<mode>_b<B>, us_per_batch, samples_per_s
           impact_compressed/<int8|packed>_b<B>, us_per_batch, s/s
           impact_sharded/<single|sharded>_xla_b<B>, us_per_batch, s/s
           impact_serve/<mode>, p95_us, samples_per_s
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ARTIFACTS, emit
from .roofline import impact_roofline

from repro.core import CoTMConfig
from repro.impact import (IMPACTConfig, RuntimeSpec, Topology, build_system)
from repro.impact.costmodel import bench_section, bytes_per_sweep
from repro.train.compression import prune_clauses
from repro.serve import (IMPACTEngine, ModelZoo, SLOClass, poisson_arrivals,
                         replay_trace, replay_zoo_trace)

BATCH_SIZES = (32, 128, 512)
QUICK_BATCH_SIZES = (8, 32)
REPEATS = 3


def _random_cotm(key, K=1568, n=500, m=10, n_states=128, density=0.05):
    """Random (untrained) CoTM at paper dims — throughput does not depend
    on training quality, and this keeps the benchmark CPU-budget friendly."""
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    k1, k2 = jax.random.split(key)
    ta = jnp.where(jax.random.bernoulli(k1, density, (K, n)),
                   n_states + 1, n_states).astype(jnp.int32)
    w = jax.random.randint(k2, (m, n), -40, 40).astype(jnp.int32)
    params = cfg.init(key)
    params = type(params)(ta_state=ta, weights=w)
    return cfg, params


def _time_predict(session, lits) -> float:
    preds = session.predict(lits).predictions   # compile + warm
    jax.block_until_ready(preds)
    t0 = time.time()
    for _ in range(REPEATS):
        jax.block_until_ready(session.predict(lits).predictions)
    return (time.time() - t0) / REPEATS


def throughput_sweep(system, cfg, *, quick: bool) -> dict:
    """Predict-path + engine-front samples/s; returns the BENCH payload."""
    rng = np.random.default_rng(0)
    results: dict[str, dict] = {}
    batch_sizes = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    sessions = {impl: system.compile(RuntimeSpec(backend=impl,
                                                 metering="off"))
                for impl in ("xla", "pallas")}
    for B in batch_sizes:
        lits = jnp.asarray(rng.random((B, cfg.n_literals)) < 0.5)
        for impl, session in sessions.items():
            dt = _time_predict(session, lits)
            key = f"{impl}_b{B}"
            results[key] = dict(us_per_batch=dt * 1e6,
                                samples_per_s=B / dt)
            emit(f"impact_throughput/{key}", dt * 1e6, f"{B / dt:.1f}")

    # Batched front end: request burst through the continuous scheduler.
    B = max(batch_sizes)
    lits = np.asarray(rng.random((B, cfg.n_literals)) < 0.5)
    eng = IMPACTEngine(system.compile(RuntimeSpec(
        backend="xla", metering="off", capacity=min(B, 128))))
    t0 = time.time()
    _, stats = eng.run(lits)
    dt = time.time() - t0
    results["engine_xla_burst"] = dict(
        us_per_batch=dt * 1e6 / stats["batches"], samples_per_s=B / dt)
    emit("impact_throughput/engine_xla_burst", dt * 1e6 / stats["batches"],
         f"{B / dt:.1f}")

    # Machine-portable gate metric: every samples/s ratioed to its OWN
    # backend family's reference at the smallest batch.  Pallas interpret
    # mode is mostly single-threaded interpreter work while the XLA
    # einsum scales with CPU threads, so a cross-family ratio would shift
    # with core count; within a family the machine-speed factor cancels
    # and batch-scaling / engine-overhead regressions still show.
    def family(key: str) -> str:
        return "pallas" if key.startswith("pallas") else "xla"

    refs = {fam: results[f"{fam}_b{batch_sizes[0]}"]["samples_per_s"]
            for fam in ("xla", "pallas")}
    return dict(
        dims=dict(K=cfg.n_literals, n=cfg.n_clauses, m=cfg.n_classes),
        quick=quick,
        reference_keys={fam: f"{fam}_b{batch_sizes[0]}" for fam in refs},
        machine=dict(cpu_count=os.cpu_count()),
        results=results,
        normalized={k: v["samples_per_s"] / refs[family(k)]
                    for k, v in results.items()})


def _time_step(session, lits, valid) -> float:
    res = session.infer_step(lits, valid)       # compile + warm
    jax.block_until_ready((res.predictions, res.e_clause_lanes))
    t0 = time.time()
    for _ in range(REPEATS):
        out = session.infer_step(lits, valid)
        jax.block_until_ready((out.predictions, out.e_clause_lanes))
    return (time.time() - t0) / REPEATS


def metered_sweep(system, cfg, *, quick: bool) -> dict:
    """The ``metered_fused`` acceptance sample: fused-metered vs
    unmetered-fused vs staged-metered ``infer_step`` samples/s, plus the
    parity record ``check_perf.py`` gates on (fused and staged meters
    must agree — billing at speed is only a win if the joules are the
    same).  Pallas family throughout: the fused kernel is the production
    path the meter rides."""
    rng = np.random.default_rng(0)
    batch_sizes = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    sessions = {mode: system.compile(RuntimeSpec(backend="pallas",
                                                 metering=mode))
                for mode in ("off", "fused", "staged")}
    results: dict[str, dict] = {}
    parity_ok = True
    for B in batch_sizes:
        lits = jnp.asarray(rng.random((B, cfg.n_literals)) < 0.5)
        valid = np.ones((B,), bool)
        res = {mode: s.infer_step(lits, valid)
               for mode, s in sessions.items()}
        parity_ok &= bool(
            (np.asarray(res["fused"].predictions)
             == np.asarray(res["staged"].predictions)).all())
        # atol=0: per-lane energies are ~1e-11 J, far below np.allclose's
        # default atol=1e-8 — the relative tolerance must do all the work
        # or an all-zeros meter regression would pass as "parity".
        parity_ok &= bool(np.allclose(
            np.asarray(res["fused"].e_clause_lanes),
            np.asarray(res["staged"].e_clause_lanes), rtol=1e-4, atol=0.0))
        parity_ok &= bool(np.allclose(
            np.asarray(res["fused"].e_class_lanes),
            np.asarray(res["staged"].e_class_lanes), rtol=1e-4, atol=0.0))
        for mode, session in sessions.items():
            dt = _time_step(session, lits, valid)
            key = f"metered_{mode}_b{B}"
            results[key] = dict(us_per_batch=dt * 1e6,
                                samples_per_s=B / dt)
            emit(f"impact_metered/{mode}_b{B}", dt * 1e6, f"{B / dt:.1f}")
    return dict(
        quick=quick, parity_ok=parity_ok, results=results,
        ratio_fused_metered_over_unmetered={
            f"b{B}": (results[f"metered_fused_b{B}"]["samples_per_s"]
                      / results[f"metered_off_b{B}"]["samples_per_s"])
            for B in batch_sizes},
        ratio_fused_metered_over_staged={
            f"b{B}": (results[f"metered_fused_b{B}"]["samples_per_s"]
                      / results[f"metered_staged_b{B}"]["samples_per_s"])
            for B in batch_sizes})


def compressed_sweep(system, cfg, *, quick: bool) -> dict:
    """The compressed-datapath acceptance sample: ``pallas-packed``
    (2-bit ternary clause codes, four cells per byte, in-kernel dequant)
    vs the int8-literal fused kernel, argmax-parity-checked against the
    einsum oracle, with the per-batch byte-traffic record
    (``costmodel.bytes_per_sweep``) ``check_perf.py`` gates at >= 4x.

    The pruning record runs ``prune_clauses`` against a calibration
    batch drawn at 95% ones-density: at the benchmark's 5% include
    density a clause carries ~78 include literals, so uniform 50/50
    literals fire nothing (P ~ 2^-78) while 95%-ones rows fire each
    clause with P ~ 0.018/row — a realistic mix of firing and dead
    columns instead of an all-dead or all-alive degenerate record.
    """
    rng = np.random.default_rng(0)
    batch_sizes = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    sessions = dict(
        int8=system.compile(RuntimeSpec(backend="pallas", metering="off")),
        packed=system.compile(RuntimeSpec(
            backend="pallas-packed", metering="off", packing="2bit")),
        oracle=system.compile(RuntimeSpec(backend="xla", metering="off")))
    results: dict[str, dict] = {}
    cost: dict[str, dict] = {}
    parity_ok = True
    for B in batch_sizes:
        lits = jnp.asarray(rng.random((B, cfg.n_literals)) < 0.5)
        preds = {kind: np.asarray(s.predict(lits).predictions)
                 for kind, s in sessions.items()}
        parity_ok &= bool((preds["packed"] == preds["int8"]).all())
        parity_ok &= bool((preds["packed"] == preds["oracle"]).all())
        for kind in ("int8", "packed"):
            dt = _time_predict(sessions[kind], lits)
            key = f"{kind}_b{B}"
            results[key] = dict(us_per_batch=dt * 1e6,
                                samples_per_s=B / dt)
            emit(f"impact_compressed/{key}", dt * 1e6, f"{B / dt:.1f}")
        c8 = bytes_per_sweep(sessions["int8"], "predict", B)
        cp = bytes_per_sweep(sessions["packed"], "predict", B)
        cost[f"b{B}"] = dict(
            int8=c8, packed=cp,
            ratio_bytes_accessed=(c8["bytes_accessed"]
                                  / max(cp["bytes_accessed"], 1.0)),
            ratio_input_bytes=(c8["input_bytes"]
                               / max(cp["input_bytes"], 1.0)))

    calib = jnp.asarray(rng.random((64, cfg.n_literals)) < 0.95)
    pruned, stats = prune_clauses(system, calib)
    sess_pruned = pruned.compile(RuntimeSpec(
        backend="pallas-packed", metering="off", packing="2bit"))
    sess_oracle = pruned.compile(RuntimeSpec(backend="xla", metering="off"))
    prune_parity = bool(
        (np.asarray(sess_pruned.predict(calib).predictions)
         == np.asarray(sess_oracle.predict(calib).predictions)).all())
    return dict(
        quick=quick, parity_ok=parity_ok, results=results,
        cost_analysis=cost,
        pruning=dict(dataclasses.asdict(stats),
                     packed_parity_on_calibration=prune_parity))


def sharded_sweep(cfg, params, *, quick: bool) -> dict | None:
    """Sharded-vs-single-device ``predict`` at a Fig. 14 split layout.

    The paper's MNIST layout fits one tile (R=S=1), so the grid is
    rebuilt with R=2 literal row-shards and S=2 class row-shards and
    served from a (data, model=2) mesh via the session topology; the
    same split system compiled without a mesh is the baseline, and
    argmax parity between the two is asserted and recorded.  Returns
    None on single-device hosts (the CI multi-device leg runs this with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on CPU the
    numbers gauge partitioning + psum overhead, not TPU speed).
    """
    n_dev = jax.device_count()
    if n_dev < 2 or n_dev % 2:
        return None
    from repro.launch.mesh import make_crossbar_mesh

    mesh = make_crossbar_mesh(n_model=2)
    split = IMPACTConfig(variability=False, finetune=False,
                         max_tile_rows=cfg.n_literals // 2,
                         max_class_rows=-(-cfg.n_clauses // 2))
    system = build_system(params, cfg, jax.random.key(1), split)
    R, S = system.clause_g.shape[0], system.class_g.shape[0]
    assert R == 2 and S == 2, (R, S)
    sess_single = system.compile(RuntimeSpec(backend="xla",
                                             metering="off"))
    sess_shard = system.compile(RuntimeSpec(
        backend="xla", metering="off", topology=Topology(mesh=mesh)))
    assert sess_shard.plan == (True, True), sess_shard.plan

    rng = np.random.default_rng(0)
    results: dict[str, dict] = {}
    parity_ok = True
    batch_sizes = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    for B in batch_sizes:
        lits = jnp.asarray(rng.random((B, cfg.n_literals)) < 0.5)
        p_single = np.asarray(sess_single.predict(lits).predictions)
        p_shard = np.asarray(sess_shard.predict(lits).predictions)
        parity_ok &= bool((p_single == p_shard).all())
        for key, sess in (("single", sess_single), ("sharded", sess_shard)):
            dt = _time_predict(sess, lits)
            results[f"{key}_xla_b{B}"] = dict(us_per_batch=dt * 1e6,
                                              samples_per_s=B / dt)
            emit(f"impact_sharded/{key}_xla_b{B}", dt * 1e6,
                 f"{B / dt:.1f}")
    speedup = {f"b{B}": (results[f"sharded_xla_b{B}"]["samples_per_s"]
                         / results[f"single_xla_b{B}"]["samples_per_s"])
               for B in batch_sizes}
    return dict(
        n_devices=n_dev, mesh={k: int(v) for k, v in mesh.shape.items()},
        grid=dict(R=R, S=S), quick=quick, parity_ok=parity_ok,
        results=results, speedup_sharded_over_single=speedup)


def serve_comparison(system, cfg, *, n_requests: int, rate_rps: float,
                     capacity: int, flush_wait_s: float, seed: int,
                     impl: str = "xla",
                     trace_dir: pathlib.Path | None = None) -> dict:
    """Replay one seeded Poisson trace through both scheduler modes (one
    shared compiled session — the schedulers, not the runtime, differ).
    With ``trace_dir``, each mode's run also lands a Chrome-tracing
    timeline (``SERVE_<mode>.trace.json``, loadable in Perfetto) as a CI
    artifact."""
    rng = np.random.default_rng(seed)
    lits = rng.random((n_requests, cfg.n_literals)) < 0.5
    arrivals = poisson_arrivals(n_requests, rate_rps, seed=seed)
    session = system.compile(RuntimeSpec(backend=impl, metering="off",
                                         capacity=capacity))
    out: dict = dict(seed=seed, n_requests=n_requests, rate_rps=rate_rps,
                     capacity=capacity, flush_wait_s=flush_wait_s,
                     impl=impl)
    engines = dict(
        continuous=IMPACTEngine(session, max_wait_s=0.0),
        flush=IMPACTEngine(session, mode="flush", buckets=(capacity,),
                           max_wait_s=flush_wait_s))
    for mode, eng in engines.items():
        eng.warmup()
        trace_path = (str(trace_dir / f"SERVE_{mode}.trace.json")
                      if trace_dir is not None else None)
        out[mode] = replay_trace(eng, lits, arrivals,
                                 trace_path=trace_path)
        emit(f"impact_serve/{mode}", out[mode]["p95_s"] * 1e6,
             f"{out[mode]['samples_per_s']:.1f}")
    out["p95_ratio_flush_over_continuous"] = (
        out["flush"]["p95_s"] / max(out["continuous"]["p95_s"], 1e-12))
    return out


def multi_tenant_sweep(*, n_tenants: int, n_requests: int, rate_rps: float,
                       capacity: int, seed: int,
                       trace_dir: pathlib.Path | None = None) -> dict:
    """Mixed Poisson traffic over a co-resident model zoo (>= 8 tenants,
    two SLO classes) vs N independent per-tenant engines.

    Three gated claims land in the ``multi_tenant`` section of
    ``BENCH_serve.json``:

    * **parity_mismatches == 0** — every co-resident sweep's prediction
      equals the per-tenant single-session oracle (checked exhaustively
      on a deterministic pass before the timed replay);
    * **billing_rel_err < 1e-9** — the per-tenant bill sums reproduce
      the shared batch meter (tenant-pure energy attribution);
    * **sweeps.coresident < sweeps.per_tenant_engines** — the shared
      block-diagonal grid serves the same trace in strictly fewer fused
      sweeps than one engine per tenant (the co-residency payoff).

    Per-SLO-class p99 comes from the zoo's tenant-threaded ledger; with
    ``trace_dir`` the replay lands ``SERVE_multitenant.trace.json`` (one
    Perfetto process track per tenant) as a CI artifact.
    """
    rng = np.random.default_rng(seed)
    # Small per-tenant CoTMs with distinct class counts; the combined
    # block-diagonal grid stays inside one tile (the co-residency
    # builder's constraint).
    systems, cfgs = [], []
    for t in range(n_tenants):
        cfg, params = _random_cotm(jax.random.key(100 + t), K=128, n=48,
                                   m=4 + t % 4, density=0.08)
        systems.append(build_system(
            params, cfg, jax.random.key(200 + t),
            IMPACTConfig(variability=False, finetune=False)))
        cfgs.append(cfg)
    gold = SLOClass(name="gold", priority=0, max_wait_s=0.0)
    std = SLOClass(name="standard", priority=1, target_occupancy=0.5,
                   max_wait_s=0.02)
    slo_of = lambda t: gold if t < 2 else std
    spec = RuntimeSpec(backend="xla", metering="staged")
    zoo = ModelZoo.build(
        [(f"t{t}", s, slo_of(t)) for t, s in enumerate(systems)],
        spec, capacity=capacity, clock=time.monotonic)
    zoo.warmup()

    # Oracle sessions + deterministic parity pass: mixed batches through
    # the shared grid, every prediction against the standalone session.
    oracle = [s.compile(dataclasses.replace(spec, capacity=1))
              for s in systems]
    tenant_of, rows = [], []
    for i in range(n_requests):
        t = int(rng.integers(n_tenants))
        tenant_of.append(t)
        rows.append((rng.random(cfgs[t].n_literals) < 0.5).astype(np.int8))
    mismatches = 0
    rid_to_idx = {}
    for i, (t, row) in enumerate(zip(tenant_of, rows)):
        rid_to_idx[zoo.submit(f"t{t}", row)] = i
    done = dict(zoo.drain())
    for rid, pred in done.items():
        i = rid_to_idx[rid]
        t = tenant_of[i]
        ref = int(np.asarray(oracle[t].predict(
            rows[i][None, :]).predictions)[0])
        mismatches += int(pred != ref)
    st = zoo.stats()
    bill = sum(v["e_read_j"] for v in st["per_tenant"].values())
    meter = st["energy"].read_energy_j
    billing_rel_err = abs(bill - meter) / max(meter, 1e-300)

    # Timed replay of one mixed Poisson trace -> per-SLO p99 + the
    # co-resident sweep count.
    arrivals = poisson_arrivals(n_requests, rate_rps, seed=seed)
    reqs = [(f"t{t}", row) for t, row in zip(tenant_of, rows)]
    sweeps0 = zoo.resident_sweeps + zoo.standby_sweeps
    rec0 = len(zoo.request_records)
    trace_path = (str(trace_dir / "SERVE_multitenant.trace.json")
                  if trace_dir is not None else None)
    replay = replay_zoo_trace(zoo, reqs, arrivals, trace_path=trace_path)
    coresident_sweeps = (zoo.resident_sweeps + zoo.standby_sweeps
                         - sweeps0)
    # Per-SLO-class tails over the TIMED replay only (the parity pass
    # above also lands in the zoo's lifetime ledger).
    from repro.serve import latency_percentiles
    slo_name = {f"t{t}": slo_of(t).name for t in range(n_tenants)}
    slo_lat: dict[str, list[float]] = {}
    for r in zoo.request_records[rec0:]:
        slo_lat.setdefault(slo_name[r.tenant], []).append(r.latency_s)
    per_slo = {name: dict(priority=(gold if name == "gold"
                                    else std).priority,
                          **latency_percentiles(lat))
               for name, lat in slo_lat.items()}

    # Baseline: the same per-tenant sub-traces through N independent
    # engines (same capacity/policy knobs), counting their sweeps.
    per_engine_sweeps = 0
    for t in range(n_tenants):
        idx = [i for i in range(n_requests) if tenant_of[i] == t]
        if not idx:
            continue
        slo = slo_of(t)
        eng = IMPACTEngine(
            systems[t].compile(dataclasses.replace(spec,
                                                   capacity=capacity)),
            max_wait_s=slo.max_wait_s,
            target_occupancy=slo.target_occupancy,
            clock=time.monotonic)
        eng.warmup()
        sub_arrivals = arrivals[idx] - arrivals[idx[0]]
        replay_trace(eng, np.stack([rows[i] for i in idx]), sub_arrivals)
        per_engine_sweeps += len(eng.batch_stats)

    out = dict(
        n_tenants=n_tenants, n_requests=n_requests, rate_rps=rate_rps,
        capacity=capacity, seed=seed, impl=spec.backend,
        parity_checked=len(done), parity_mismatches=mismatches,
        billing_rel_err=billing_rel_err,
        sweeps=dict(coresident=coresident_sweeps,
                    per_tenant_engines=per_engine_sweeps),
        completed=replay["completed"], shed=replay["shed"],
        samples_per_s=replay["samples_per_s"],
        per_slo={name: dict(priority=d["priority"], p50_s=d["p50_s"],
                            p99_s=d["p99_s"], n=d["n"])
                 for name, d in per_slo.items()},
        per_tenant={tid: dict(completed=d["completed"], shed=d["shed"],
                              e_read_j=d["e_read_j"])
                    for tid, d in replay["zoo"]["per_tenant"].items()},
    )
    if trace_path is not None:
        out["trace_path"] = trace_path
    for name, d in sorted(per_slo.items()):
        emit(f"impact_multitenant/{name}", d["p99_s"] * 1e6,
             f"n={d['n']}")
    emit("impact_multitenant/sweeps",
         float(coresident_sweeps),
         f"vs {per_engine_sweeps} per-tenant")
    return out


def main(quick: bool = False, json_dir: pathlib.Path | None = None) -> None:
    json_dir = pathlib.Path(json_dir) if json_dir else ARTIFACTS
    json_dir.mkdir(parents=True, exist_ok=True)
    key = jax.random.key(0)
    cfg, params = _random_cotm(key)
    # Ideal devices: benchmark the inference path, not encode stochasticity.
    system = build_system(params, cfg, jax.random.key(1),
                          IMPACTConfig(variability=False, finetune=False))

    bench = throughput_sweep(system, cfg, quick=quick)
    bench["metered"] = metered_sweep(system, cfg, quick=quick)
    bench["compressed"] = compressed_sweep(system, cfg, quick=quick)
    # Calibrated analytic cost model over the sessions the sweeps just
    # timed (compile cache hit — no re-lowering): predicted-vs-measured
    # ratios check_perf.py gates per backend and metering mode.
    bench["predicted_vs_measured"] = bench_section(
        system, bench,
        batch_sizes=QUICK_BATCH_SIZES if quick else BATCH_SIZES)
    # Roofline placement of the same executables (XLA cost counters vs
    # the v5e peaks) — recorded for the scoreboard, not gated.
    bench["roofline"] = impact_roofline(
        system, bench["results"],
        batch_sizes=QUICK_BATCH_SIZES if quick else BATCH_SIZES)
    sharded = sharded_sweep(cfg, params, quick=quick)
    if sharded is not None:            # multi-device hosts only
        bench["sharded"] = sharded
    with open(json_dir / "BENCH_throughput.json", "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)

    serve = serve_comparison(
        system, cfg,
        n_requests=80 if quick else 256,
        rate_rps=300.0, capacity=16 if quick else 32,
        flush_wait_s=0.05, seed=0, trace_dir=json_dir)
    serve["multi_tenant"] = multi_tenant_sweep(
        n_tenants=8, n_requests=96 if quick else 320,
        rate_rps=400.0, capacity=16, seed=0, trace_dir=json_dir)
    with open(json_dir / "BENCH_serve.json", "w") as f:
        json.dump(serve, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    import warnings

    from repro.impact import SpecDeprecationWarning

    # The CI perf legs invoke this module directly: enforce the
    # migration off the deprecated per-call kwargs here too (pytest.ini
    # covers the test suite, benchmarks/run.py the orchestrator).
    warnings.simplefilter("error", SpecDeprecationWarning)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI perf-smoke scale: B<=32 sweep, short trace")
    ap.add_argument("--json-dir", default=None,
                    help="where BENCH_*.json land (default: artifacts/)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick, json_dir=args.json_dir)
