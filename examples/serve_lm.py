"""Serving driver: batched requests through prefill + decode.

Builds a reduced model, enqueues ragged requests through the batching
queue, and streams greedy/temperature generations — the same
prefill/decode entry points the multi-pod dry-run lowers at 32k/500k.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b
      PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --tokens 64
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build
from repro.serve import BatchingQueue, Engine, Request, ServeConfig

from train_lm import hundred_m_variant  # noqa: E402  (sibling example)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_IDS)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = hundred_m_variant(get_config(args.arch))
    model = build(cfg)
    params = model.init(jax.random.key(0))
    print(f"{args.arch} (reduced): {model.n_params() / 1e6:.1f}M params")

    engine = Engine(model, params,
                    ServeConfig(max_len=256,
                                temperature=args.temperature))

    # Ragged requests arrive; the queue batches and pads them.
    rng = np.random.default_rng(0)
    queue = BatchingQueue(max_batch=4, max_wait_s=0.01)
    for rid in range(args.requests):
        plen = int(rng.integers(8, 24))
        queue.add(Request(rid, rng.integers(
            0, cfg.vocab, plen).astype(np.int32), args.tokens))

    served = 0
    while queue.pending:
        time.sleep(0.02)
        if not queue.ready():
            continue
        batch = queue.take()
        toks, mask = BatchingQueue.pad(batch)
        gen, stats = engine.generate(toks, args.tokens,
                                     seed=served)
        served += len(batch)
        print(f"batch of {len(batch)}: prefill {stats['prefill_s']:.2f}s, "
              f"decode {stats['decode_tok_per_s']:.1f} tok/s")
        for r, row in zip(batch, np.asarray(gen)):
            print(f"  req {r.rid}: prompt[{len(r.tokens)}] -> "
                  f"{row.flatten()[:8].tolist()}...")
    print(f"served {served} requests")


if __name__ == "__main__":
    main()
