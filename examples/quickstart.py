"""Quickstart: the paper's full pipeline in one script.

1. generate a synthetic MNIST-like dataset and booleanize it;
2. train a Coalesced Tsetlin Machine (500 clauses, 10 classes);
3. map the trained TAs + weights onto Y-Flash crossbar tiles (Boolean
   encode + two-phase analog tuning, full C2C/D2D variability);
4. compile the programmed system into an InferenceSession (a frozen
   RuntimeSpec resolved once: backend, topology, metering) and run
   in-memory inference, printing the paper's Table-4 metrics;
5. cross-check the Pallas kernels against the digital twin.

Run:  PYTHONPATH=src python examples/quickstart.py [--epochs 10]
"""
import argparse
import pathlib
import sys
import time
import warnings

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CoTMConfig, booleanize, include_mask, predict,
                        train_epochs)
from repro.data.synthetic import digits
from repro.impact import RuntimeSpec, build_system
from repro.kernels import ops


def main() -> None:
    # Examples document the supported API: fail loudly if one slips back
    # onto the deprecated per-call kwargs.
    from repro.impact import SpecDeprecationWarning
    warnings.simplefilter("error", SpecDeprecationWarning)
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--clauses", type=int, default=500)
    ap.add_argument("--train", type=int, default=8000)
    args = ap.parse_args()

    print("== 1. data ==")
    x_tr, y_tr = digits(args.train, seed=1, jitter=2)
    x_te, y_te = digits(1000, seed=2, jitter=2)
    lit_tr = booleanize(jnp.asarray(x_tr))
    lit_te = booleanize(jnp.asarray(x_te))
    print(f"train {lit_tr.shape} literals, test {lit_te.shape}")

    print("== 2. CoTM training ==")
    cfg = CoTMConfig(n_literals=1568, n_clauses=args.clauses, n_classes=10,
                     n_states=128, threshold=96, specificity=8.0)
    params = cfg.init(jax.random.key(0))
    t0 = time.time()
    for ep in range(args.epochs):
        params = train_epochs(params, lit_tr, jnp.asarray(y_tr),
                              jax.random.fold_in(jax.random.key(1), ep),
                              cfg, epochs=1, batch_size=32)
        acc = float((predict(params, lit_te, cfg)
                     == jnp.asarray(y_te)).mean())
        print(f"  epoch {ep}: test acc {acc:.3f} ({time.time() - t0:.0f}s)")
    sw_acc = acc

    print("== 3. crossbar mapping (Y-Flash digital twin) ==")
    t0 = time.time()
    system = build_system(params, cfg, jax.random.key(2))
    st = system.encode_stats
    print(f"  clause tile: {system.clause_g.shape} "
          f"(include frac {float(st['clause']['include_fraction']):.3%}, "
          f"paper: 2.32%)")
    print(f"  mean encode pulses "
          f"{float(st['clause']['prog_pulses'].mean()):.1f} (paper ~7)")
    print(f"  weight shift |W_min| = {st['weight_shift']} "
          f"(paper Fig. 6 unipolar transform)")
    print(f"  mapped in {time.time() - t0:.0f}s")

    print("== 4. in-memory inference (compiled session) ==")
    # Runtime configuration is declared ONCE: the spec picks the backend
    # (any registered lowering), topology, and metering mode, and
    # compile() resolves it into AOT executables.  The serving engine
    # takes the same session (IMPACTEngine(system.compile(spec))).
    # metering="fused" accumulates the Table 4 energy meters INSIDE the
    # fused kernel, so the report below costs no staged second pass.
    session = system.compile(RuntimeSpec(backend="pallas",
                                         metering="fused"))
    result = session.infer_with_report(lit_te)
    preds, report = result.predictions, result.report
    hw_acc = float((preds == jnp.asarray(y_te)).mean())
    print(f"  software acc {sw_acc:.3f} | hardware acc {hw_acc:.3f} "
          "(paper: 0.963 sw == hw)")
    print(f"  energy/datapoint: clause {report.clause_energy_j / 1000 * 1e12:.1f} pJ "
          "(paper 67.99), "
          f"class {report.class_energy_j / 1000 * 1e12:.1f} pJ (paper 16.22)")
    print(f"  GOPS {report.gops:.1f} (paper 413.6) | "
          f"TOPS/W {report.tops_per_w:.1f} (paper 24.56)")

    print("== 5. Pallas kernel cross-check ==")
    inc = include_mask(params.ta_state, cfg.n_states)
    scores = ops.fused_cotm(lit_te[:256], inc, params.weights.T)
    k_acc = float((jnp.argmax(scores, -1) == jnp.asarray(y_te)[:256]).mean())
    sw = predict(params, lit_te[:256], cfg)
    agree = float((jnp.argmax(scores, -1) == sw).mean())
    print(f"  fused_cotm kernel acc {k_acc:.3f}, agreement with software "
          f"{agree:.1%}")


if __name__ == "__main__":
    main()
