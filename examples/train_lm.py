"""End-to-end LM training driver: ~100M-param model, few hundred steps.

Exercises the full substrate on one host: config -> model build -> AdamW +
grad accumulation -> fault-tolerant TrainLoop (auto-resume, heartbeats,
async checkpoints) -> loss curve.  Pass ``--arch`` for any of the 10
assigned architectures (a width/depth-reduced variant sized near 100M
params is derived automatically).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --arch rwkv6-7b --steps 50
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import synth_tokens
from repro.models import build
from repro.train import (AdamWConfig, RuntimeConfig, TrainLoop, init_state,
                         make_train_step)


def hundred_m_variant(cfg):
    """Shrink an assigned config toward ~100M params, same family."""
    changes = dict(n_layers=min(cfg.n_layers, 8), d_model=512,
                   n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4),
                   head_dim=64, d_ff=1536, vocab=min(cfg.vocab, 32768),
                   attn_chunk_q=128, attn_chunk_k=256, remat=False)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 8), top_k=2,
            d_ff_expert=768,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_ff_dense=1536 if cfg.moe.d_ff_dense else None)
    if cfg.mla is not None:
        changes["mla"] = dataclasses.replace(cfg.mla, kv_lora_rank=128,
                                             qk_nope_head_dim=32,
                                             qk_rope_head_dim=16,
                                             v_head_dim=32)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, chunk=32)
        changes["n_layers"] = min(cfg.n_layers, 12)
    if cfg.hybrid_attn_every:
        changes["hybrid_attn_every"] = 4
    return dataclasses.replace(cfg, **changes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_variant(get_config(args.arch))
    model = build(cfg)
    print(f"{args.arch} (reduced): {model.n_params() / 1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20)
    params = model.init(jax.random.key(0))
    state = init_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))

    tokens = synth_tokens(cfg, args.batch * 16, args.seq)

    def data():
        i = 0
        while True:
            lo = (i * args.batch) % (tokens.shape[0] - args.batch)
            batch = tokens[lo:lo + args.batch]
            if cfg.modality == "audio":
                yield {"tokens": batch[None]}
            else:
                yield {"tokens": batch[None]}
            i += 1

    loop = TrainLoop(step, state, data(),
                     RuntimeConfig(ckpt_dir=args.ckpt_dir,
                                   max_steps=args.steps, save_every=50))
    start = loop.maybe_resume()
    if start:
        print(f"auto-resumed from step {start}")
    loop.run(seed=0)
    losses = [m["loss"] for m in loop.metrics_log]
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"loss: first10={np.mean(losses[:k]):.3f} "
              f"last10={np.mean(losses[-k:]):.3f} "
              f"steps={len(losses)} stragglers={loop.straggler_events}")
        assert losses and np.mean(losses[-k:]) < np.mean(losses[:k]), \
            "loss did not decrease"
        print("OK: loss decreased")


if __name__ == "__main__":
    main()
