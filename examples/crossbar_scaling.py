"""Fig. 14 scaling demo: one logical CoTM split across many crossbar tiles.

Shows the paper's modular scaling scheme at work: as the tile size limit
shrinks, literals split across row shards (partial clauses combined by the
digital AND) and clauses split across class-tile shards (partial sums
summed after ADC) — predictions stay IDENTICAL, tile counts grow, and the
same split maps 1:1 onto the distributed model-axis sharding (psum of
violation counts / partial class sums).

Run:  PYTHONPATH=src python examples/crossbar_scaling.py
"""
import pathlib
import sys
import warnings

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoTMConfig, predict, train_epochs
from repro.data.synthetic import prototype
from repro.impact import IMPACTConfig, RuntimeSpec, build_system


def main() -> None:
    # Examples document the supported API: fail loudly if one slips back
    # onto the deprecated per-call kwargs.
    from repro.impact import SpecDeprecationWarning
    warnings.simplefilter("error", SpecDeprecationWarning)
    cfg = CoTMConfig(n_literals=256, n_clauses=128, n_classes=6,
                     n_states=64, threshold=24, specificity=5.0)
    x, y = prototype(1024, n_classes=6, n_features=128, flip=0.05)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    params = train_epochs(cfg.init(jax.random.key(0)), lits, labels,
                          jax.random.key(1), cfg, epochs=8, batch_size=64)
    sw_acc = float((predict(params, lits, cfg) == labels).mean())
    print(f"software CoTM accuracy: {sw_acc:.3f}")
    print(f"{'tile limit':>12} {'clause tiles':>13} {'class shards':>13} "
          f"{'agreement':>10} {'acc':>6}")

    base = None
    for rows, cols in [(2048, 512), (128, 64), (64, 32), (32, 16)]:
        icfg = IMPACTConfig(variability=False, finetune=False,
                            max_tile_rows=rows, max_tile_cols=cols,
                            max_class_rows=cols)
        system = build_system(params, cfg, jax.random.key(2), icfg)
        session = system.compile(RuntimeSpec())     # default pallas spec
        preds = np.asarray(session.predict(lits[:512]).predictions)
        if base is None:
            base = preds
        agree = (preds == base).mean()
        acc = (preds == np.asarray(labels[:512])).mean()
        R, C = system.clause_g.shape[0], system.clause_g.shape[1]
        S = system.class_g.shape[0]
        print(f"{rows}x{cols:>5} {R * C:>13} {S:>13} {agree:>10.1%} "
              f"{acc:>6.3f}")
    print("identical predictions across tilings == Fig. 14 partial-clause "
          "AND / partial-sum ADC combine verified")


if __name__ == "__main__":
    main()
